//! Differential-oracle acceptance test (DESIGN.md §Oracle): ≥ 10k fuzzed
//! vectors per format — rotating through uniform full-range,
//! subnormal-dense, cancellation-heavy and mixed-sign near-overflow
//! distributions — must produce **zero** exact-mode mismatches between any
//! algorithm × radix-config × accumulator-path combination (the batched
//! SoA kernel included, both inside `run_oracle`'s rotation and through a
//! dedicated per-block-size gate below) and the independent sign-magnitude
//! reference. Two-term FP32 exact-mode sums must additionally bit-match
//! native `f32` addition, including subnormal results.

use online_fp_add::arith::adder::{Architecture, MultiTermAdder};
use online_fp_add::arith::oracle::{reference_sum, run_oracle, OracleConfig, DISTRIBUTIONS};
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{FpClass, FP32, PAPER_FORMATS};
use online_fp_add::reduce::registry;
use online_fp_add::util::prng::XorShift;

#[test]
fn oracle_runs_clean_over_10k_vectors_per_format() {
    let cfg = OracleConfig { vectors: 10_000, terms: 16, seed: 0xD1FF_5EED };
    for fmt in PAPER_FORMATS {
        let rep = run_oracle(fmt, &cfg);
        assert_eq!(rep.vectors, 10_000, "{fmt}");
        assert!(
            rep.mismatches.is_empty(),
            "{fmt}: {} exact-mode mismatches, first: {:?}",
            rep.mismatches.len(),
            rep.mismatches.first()
        );
        // Every vector ran through at least 4 architecture combinations.
        assert!(rep.exact_checks >= 40_000, "{fmt}: {}", rep.exact_checks);
        // The truncated hw-default datapath met the faithfulness filter on
        // a healthy share of vectors and stayed within the documented
        // bound.
        assert!(rep.truncated_checks > 0, "{fmt}");
        assert!(
            rep.truncated_max_ulp <= 2,
            "{fmt}: truncated deviation {} ulp",
            rep.truncated_max_ulp
        );
    }
}

#[test]
fn every_registered_backend_runs_clean_against_the_oracle_on_every_distribution() {
    // The same adversarial distributions, driven explicitly through every
    // backend the registry knows — block-taking backends at several block
    // sizes, narrow and wide accumulator paths where the format offers
    // both — with the same zero-mismatch gate against the big-int
    // reference. A newly registered backend is covered here with no edits.
    let mut rng = XorShift::new(0x4E61_D1FF);
    let n = 16usize;
    let backend_archs: Vec<Architecture> = registry::entries()
        .iter()
        .flat_map(|entry| {
            if entry.takes_block {
                [1usize, 3, 8, 64, n]
                    .iter()
                    .map(|&b| {
                        Architecture::Backend(entry.sel().with_block(b).expect("valid block"))
                    })
                    .collect::<Vec<_>>()
            } else {
                vec![Architecture::Backend(entry.sel())]
            }
        })
        .collect();
    for fmt in PAPER_FORMATS {
        let exact = AccSpec::exact(fmt);
        let mut specs = vec![exact];
        if exact.narrow {
            specs.push(AccSpec { narrow: false, ..exact });
        }
        let mut mismatches = 0u64;
        let mut checks = 0u64;
        for dist in DISTRIBUTIONS {
            for _ in 0..250 {
                let terms = dist.gen_vector(&mut rng, fmt, n);
                let expected = reference_sum(&terms, fmt);
                for &spec in &specs {
                    for arch in &backend_archs {
                        let adder = MultiTermAdder {
                            format: fmt,
                            n_terms: n,
                            spec,
                            arch: arch.clone(),
                        };
                        checks += 1;
                        if adder.add(&terms).bits != expected.bits {
                            mismatches += 1;
                        }
                    }
                }
            }
        }
        assert_eq!(mismatches, 0, "{fmt}: backend-path oracle mismatches");
        assert!(checks >= 5_000, "{fmt}: only {checks} backend checks ran");
    }
}

#[test]
fn two_term_fp32_exact_mode_bit_matches_native_f32_including_subnormals() {
    let mut rng = XorShift::new(0xF32_ADD);
    let adder = MultiTermAdder::exact(FP32, 2, Architecture::Online);
    let mut subnormal_results = 0usize;
    for _ in 0..20_000 {
        let a = rng.gen_fp_full(FP32);
        let b = rng.gen_fp_full(FP32);
        if a.class() == FpClass::Zero && b.class() == FpClass::Zero {
            // Multi-term fused adders round all-zero sums to +0; a native
            // two-operand IEEE add keeps -0 for (-0) + (-0).
            continue;
        }
        let native = (a.to_f64() as f32) + (b.to_f64() as f32);
        let got = adder.add(&[a, b]);
        assert_eq!(
            (got.to_f64() as f32).to_bits(),
            native.to_bits(),
            "{a:?} + {b:?}"
        );
        if got.class() == FpClass::Subnormal {
            subnormal_results += 1;
        }
    }
    // The operand space genuinely exercised gradual underflow.
    assert!(subnormal_results > 0, "no subnormal results sampled");
}

#[test]
fn every_distribution_produces_what_it_promises() {
    let mut rng = XorShift::new(0x0D15);
    for fmt in PAPER_FORMATS {
        for dist in DISTRIBUTIONS {
            let terms = dist.gen_vector(&mut rng, fmt, 64);
            assert_eq!(terms.len(), 64, "{fmt} {}", dist.name());
            assert!(
                terms.iter().all(|t| t.is_finite()),
                "{fmt} {}: non-finite operand",
                dist.name()
            );
            // The reference accepts every vector without panicking and the
            // result is in-format.
            let r = reference_sum(&terms, fmt);
            assert_eq!(r.format, fmt);
        }
    }
}
