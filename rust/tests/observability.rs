//! Observability gate (DESIGN.md §Observability): the provenance hash's
//! reproducibility contract, enforced end to end.
//!
//! On an exact spec the fused `⊙` operator is associative and commutative
//! (eq. 10), so a stream's resolved `[λ; acc; sticky]` state — and
//! therefore its provenance hash, which covers exactly the value facts —
//! must be **bit-identical** under any arrival order, chunk split, shard
//! geometry, or registered backend. Each gate below shuffles one of those
//! execution axes ≥1000 times and requires a single unique hash and zero
//! state mismatches.

use std::collections::HashSet;

use online_fp_add::arith::operator::AlignAcc;
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{Fp, FpFormat, PAPER_FORMATS};
use online_fp_add::reduce::{registry, ReducePlan};
use online_fp_add::stream::{EngineConfig, StreamService};
use online_fp_add::telemetry::provenance_hash;
use online_fp_add::util::prng::XorShift;

const TERMS: usize = 48;
const TRIALS: usize = 1000;

fn workload(fmt: FpFormat, seed: u64) -> Vec<Fp> {
    let mut rng = XorShift::new(seed);
    (0..TERMS).map(|_| rng.gen_fp_sparse(fmt, 0.1)).collect()
}

/// Reduce `terms` through `plan` in random-sized chunks (a fresh reducer,
/// chunk boundaries drawn from `rng`), returning the resolved state.
fn chunked_reduce(plan: &ReducePlan, terms: &[Fp], rng: &mut XorShift) -> AlignAcc {
    let mut reducer = plan.reducer();
    let mut rest = terms;
    while !rest.is_empty() {
        let take = 1 + rng.below(rest.len().min(17) as u64) as usize;
        reducer.ingest(&rest[..take]);
        rest = &rest[take..];
    }
    assert_eq!(reducer.terms(), terms.len() as u64);
    reducer.finish()
}

#[test]
fn provenance_hash_is_invariant_to_arrival_order_and_chunking() {
    for (f, fmt) in PAPER_FORMATS.iter().enumerate() {
        let spec = AccSpec::exact(*fmt);
        let base = workload(*fmt, 0xAB5EED ^ ((f as u64) << 8));
        for entry in registry::entries() {
            let plan = ReducePlan::with_backend(spec, entry.sel());
            let mut rng = XorShift::new(0xC0FFEE ^ (f as u64));
            let mut terms = base.clone();
            let reference = chunked_reduce(&plan, &terms, &mut rng);
            let mut hashes = HashSet::new();
            let mut mismatches = 0usize;
            for _ in 0..TRIALS {
                rng.shuffle(&mut terms);
                let out = chunked_reduce(&plan, &terms, &mut rng);
                if out != reference {
                    mismatches += 1;
                }
                hashes.insert(provenance_hash(
                    fmt.name,
                    spec,
                    terms.len() as u64,
                    out.lambda,
                    &out.acc,
                    out.sticky,
                ));
            }
            assert_eq!(mismatches, 0, "{} {}: shuffled states diverged", fmt.name, entry.name);
            assert_eq!(
                hashes.len(),
                1,
                "{} {}: {TRIALS} shuffled trials produced {} distinct provenance hashes",
                fmt.name,
                entry.name,
                hashes.len()
            );
        }
    }
}

#[test]
fn provenance_hash_is_invariant_across_backends() {
    // The same multiset of terms through every registered backend must
    // collapse to one hash per format — the backend is execution shape,
    // not a value fact.
    for (f, fmt) in PAPER_FORMATS.iter().enumerate() {
        let spec = AccSpec::exact(*fmt);
        let terms = workload(*fmt, 0xBAC6E ^ ((f as u64) << 4));
        let mut rng = XorShift::new(0x5EED ^ (f as u64));
        let hashes: HashSet<u64> = registry::entries()
            .iter()
            .map(|entry| {
                let plan = ReducePlan::with_backend(spec, entry.sel());
                let out = chunked_reduce(&plan, &terms, &mut rng);
                provenance_hash(
                    fmt.name,
                    spec,
                    terms.len() as u64,
                    out.lambda,
                    &out.acc,
                    out.sticky,
                )
            })
            .collect();
        assert_eq!(hashes.len(), 1, "{}: backends disagree on the provenance hash", fmt.name);
    }
}

#[test]
fn served_provenance_is_invariant_to_ingest_order_shard_split_and_backend() {
    use online_fp_add::formats::BF16;
    let spec = AccSpec::exact(BF16);
    let terms = workload(BF16, 0x0B5E);
    let mut rng = XorShift::new(0x51AB);
    let mut hashes = HashSet::new();
    let mut values = HashSet::new();
    // Every registered backend × several engine geometries × shuffled
    // batching of the same multiset: the served value and its audit hash
    // must never move.
    for entry in registry::entries() {
        for (threads, stripes) in [(1usize, 1usize), (2, 3), (4, 8)] {
            let cfg = EngineConfig {
                threads,
                stripes,
                spec,
                backend: Some(entry.sel()),
                ..Default::default()
            };
            let svc = StreamService::new(BF16, cfg);
            let mut order = terms.clone();
            rng.shuffle(&mut order);
            let mut rest = &order[..];
            while !rest.is_empty() {
                let take = 1 + rng.below(rest.len().min(11) as u64) as usize;
                svc.ingest_blocking("obs", rest[..take].to_vec()).expect("engine alive");
                rest = &rest[take..];
            }
            let (value, rec) = svc.query_with_provenance("obs").expect("stream exists");
            assert_eq!(rec.terms, terms.len() as u64);
            assert_eq!(rec.backend, entry.name);
            // Draining re-cuts the record from the same final state.
            let (dvalue, drec) = svc.drain_with_provenance("obs").expect("stream exists");
            assert_eq!(dvalue.bits, value.bits);
            assert_eq!(drec.hash, rec.hash);
            hashes.insert(rec.hash);
            values.insert(value.bits);
        }
    }
    assert_eq!(hashes.len(), 1, "served provenance hash moved across execution shapes");
    assert_eq!(values.len(), 1, "served value moved across execution shapes");
}
