//! Registry conformance gate (DESIGN.md §Reducer): every backend the
//! registry knows — present and future — runs the same acceptance battery
//! with **zero** failures, across all five paper formats. The battery
//! itself lives in `reduce::conformance` so the `repro conform` CLI and
//! this gate share one implementation; registering a new backend (the
//! SIMD kernel variant the ROADMAP names, a GPU fold, …) puts it in front
//! of these gates with no test edits at all.

use online_fp_add::formats::PAPER_FORMATS;
use online_fp_add::reduce::conformance::{run_format, ConformanceConfig};
use online_fp_add::reduce::registry;

#[test]
fn every_registered_backend_conforms_on_every_format() {
    let cfg = ConformanceConfig::default();
    for fmt in PAPER_FORMATS {
        let reports = run_format(fmt, &cfg);
        assert_eq!(
            reports.len(),
            registry::entries().len(),
            "{fmt}: one report per registered backend"
        );
        for rep in reports {
            assert!(
                rep.clean(),
                "{fmt} {}: reduce={} split={} merge={} codec={} specials={} ({} checks)",
                rep.backend,
                rep.reduce_mismatches,
                rep.split_mismatches,
                rep.merge_mismatches,
                rep.codec_failures,
                rep.specials_failures,
                rep.checks,
            );
            assert!(rep.checks >= 400, "{fmt} {}: only {} checks ran", rep.backend, rep.checks);
        }
    }
}

#[test]
fn conformance_is_deterministic_for_a_fixed_seed() {
    // The battery is seeded: two runs must agree check-for-check, so a CI
    // failure reproduces locally.
    let cfg = ConformanceConfig { vectors: 5, max_terms: 48, seed: 0xD5EED };
    let fmt = PAPER_FORMATS[0];
    let a = run_format(fmt, &cfg);
    let b = run_format(fmt, &cfg);
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.backend, rb.backend);
        assert_eq!(ra.checks, rb.checks);
        assert_eq!(ra.failures(), rb.failures());
    }
}
