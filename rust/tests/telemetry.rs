//! Telemetry-tier integration battery (DESIGN.md §Observability).
//!
//! Two kinds of tests live here:
//!
//! * **Primitive/local-hub tests** — concurrency exactness of the lock-free
//!   cells, histogram quantile bracketing, and snapshot/exposition golden
//!   output against a *local* [`Telemetry`] hub. These touch no shared
//!   state and run freely in parallel.
//! * **Global-hub tests** — numeric-health counters (kernel sticky/narrow
//!   paths, EIA drains, spill promotions) asserted as **exact deltas**
//!   against the process-wide hub. The instrumented code paths only ever
//!   write to [`telemetry::global`], so these serialize on one mutex; all
//!   assertions are before/after differences, never absolute values, so
//!   they stay correct regardless of what ran earlier in the process.

use online_fp_add::accum::{EiaSnapshot, ExpBins};
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{Fp, BF16};
use online_fp_add::reduce::{registry, Partial, ReducePlan, Reducer};
use online_fp_add::stream::StreamService;
use online_fp_add::telemetry::{self, Counter, Gauge, MetricValue, Telemetry, ValueHistogram};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::thread;

/// Serializes every test that reads or writes the global hub. A poisoned
/// lock (a failed sibling) must not cascade — the guard is all we need.
static GLOBAL_HUB: Mutex<()> = Mutex::new(());

fn hub_lock() -> MutexGuard<'static, ()> {
    GLOBAL_HUB.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The sticky probe pair: 2^20 against 1.0 in BF16 under a 2-bit guard
/// drops the small term's bits into sticky on every backend (the same
/// fixture `accum::drain`'s unit tests pin bit-for-bit).
fn sticky_pair() -> [Fp; 2] {
    [Fp::from_f64(1048576.0, BF16), Fp::from_f64(1.0, BF16)]
}

/// The registered telemetry slot of a backend, after instrumentation has
/// initialized the registry's slot names (building any reducer does).
fn backend_slot(name: &str) -> usize {
    telemetry::global()
        .backend_slot_names()
        .iter()
        .position(|n| *n == name)
        .unwrap_or_else(|| panic!("backend slot {name:?} not registered"))
}

#[test]
fn concurrent_counter_and_gauge_updates_are_exact() {
    // The metrics contract is exactness, not sampling: N threads hammering
    // one counter must land every single update. 8 threads × 10k rounds of
    // (inc + add 2) = 240k, reconstructed without loss.
    let c = Counter::new();
    let g = Gauge::new();
    thread::scope(|s| {
        for worker in 0..8 {
            s.spawn(|| {
                for _ in 0..10_000 {
                    c.inc();
                    c.add(2);
                }
            });
            // Half the workers push the gauge up, half pull it down by the
            // same total — concurrent inc/dec must cancel to exactly zero.
            if worker % 2 == 0 {
                s.spawn(|| {
                    for _ in 0..2_000 {
                        g.add(5);
                    }
                });
            } else {
                s.spawn(|| {
                    for _ in 0..10_000 {
                        g.dec();
                    }
                });
            }
        }
    });
    assert_eq!(c.get(), 8 * 10_000 * 3);
    assert_eq!(g.get(), 0);
    c.reset();
    g.set(-7);
    assert_eq!((c.get(), g.get()), (0, -7));
}

#[test]
fn histogram_quantiles_bracket_the_true_order_statistic() {
    // Log2 buckets quantize upward: for a true quantile value v in
    // [2^i, 2^(i+1)), quantile() reports the bucket upper bound 2^(i+1),
    // so the estimate is strictly above v and at most 2v. Feed the exact
    // population 1..=1000 and check the bracket at several ranks.
    let h = ValueHistogram::new();
    for v in 1..=1000u64 {
        h.observe(v);
    }
    assert_eq!(h.count(), 1000);
    assert_eq!(h.sum(), 500_500);
    assert_eq!(h.min(), 1);
    assert_eq!(h.max(), 1000);
    assert!((h.mean() - 500.5).abs() < 1e-9);
    for q in [0.01, 0.25, 0.5, 0.9, 0.99, 1.0] {
        let true_v = (1000.0 * q).ceil() as u64; // rank k ⇒ value k here
        let est = h.quantile(q);
        assert!(
            true_v < est && est <= 2 * true_v,
            "q={q}: estimate {est} outside ({true_v}, {}]",
            2 * true_v
        );
    }
    // Concretely: the median (500) lives in [256, 512) ⇒ 512 reported.
    assert_eq!(h.quantile(0.5), 512);
    h.reset();
    assert_eq!((h.count(), h.min(), h.max()), (0, 0, 0));
    assert_eq!(h.quantile(0.5), 0, "empty histograms report 0");
}

#[test]
fn local_hub_snapshots_are_deterministic_and_exposition_is_golden() {
    // A local hub with known traffic: snapshots must be equal (the
    // determinism contract) and both expositions must render the exact
    // documented shapes — labeled counters with `_total`, bare gauges,
    // cumulative histogram buckets.
    let t = Telemetry::new();
    t.register_backend_slot(0, "scalar");
    t.register_backend_slot(1, "kernel");
    t.reduce_slot(0).ingest_terms.add(64);
    t.reduce_slot(0).reduce_calls.inc();
    t.plan.builds.add(2);
    t.accum.occupancy.observe(5);
    t.kernel.lanes.add(7);
    t.stream.queue_depth.set(2);
    t.stream.shard_merges[3].inc();
    t.stream.shard_terms[3].add(9);

    let (a, b) = (t.snapshot(), t.snapshot());
    assert_eq!(a, b);
    assert_eq!(a.counter_labeled("ofa_reduce_ingest_terms", "backend", "scalar"), 64);
    assert_eq!(a.counter("ofa_reduce_ingest_terms"), 64);
    match &a.get("ofa_accum_bin_occupancy").expect("histogram sample").value {
        MetricValue::Histogram(h) => assert_eq!((h.count, h.sum, h.min, h.max), (1, 5, 5, 5)),
        other => panic!("expected a histogram, got {other:?}"),
    }

    let prom = a.to_prometheus();
    assert_eq!(prom, b.to_prometheus());
    assert!(prom.contains("# TYPE ofa_reduce_ingest_terms counter"), "{prom}");
    assert!(prom.contains("ofa_reduce_ingest_terms_total{backend=\"scalar\"} 64"), "{prom}");
    // Registered-but-idle slots are part of the stable surface…
    assert!(prom.contains("ofa_reduce_ingest_terms_total{backend=\"kernel\"} 0"), "{prom}");
    // …while unregistered slots and untouched shard stripes are absent.
    assert!(!prom.contains("backend=\"\""), "{prom}");
    assert!(!prom.contains("shard=\"0\""), "{prom}");
    assert!(prom.contains("ofa_plan_builds_total 2"), "{prom}");
    assert!(prom.contains("ofa_kernel_lanes_total 7"), "{prom}");
    assert!(prom.contains("# TYPE ofa_stream_queue_depth gauge"), "{prom}");
    assert!(prom.contains("ofa_stream_queue_depth 2"), "{prom}");
    assert!(prom.contains("ofa_stream_shard_merges_total{shard=\"3\"} 1"), "{prom}");
    assert!(prom.contains("ofa_stream_shard_terms_total{shard=\"3\"} 9"), "{prom}");
    // observe(5) lands in bucket [4, 8) ⇒ cumulative le="8" carries it.
    assert!(prom.contains("ofa_accum_bin_occupancy_bucket{le=\"8\"} 1"), "{prom}");
    assert!(prom.contains("ofa_accum_bin_occupancy_bucket{le=\"+Inf\"} 1"), "{prom}");
    assert!(prom.contains("ofa_accum_bin_occupancy_sum 5"), "{prom}");
    assert!(prom.contains("ofa_accum_bin_occupancy_count 1"), "{prom}");

    let js = a.to_json();
    assert_eq!(js, b.to_json());
    assert!(js.contains("\"name\":\"ofa_reduce_ingest_terms\""), "{js}");
    assert!(js.contains("\"labels\":{\"backend\":\"scalar\"}"), "{js}");
    assert!(js.contains("\"labels\":{\"shard\":\"3\"}"), "{js}");
    for (open, close) in [('{', '}'), ('[', ']')] {
        let n_open = js.chars().filter(|&c| c == open).count();
        let n_close = js.chars().filter(|&c| c == close).count();
        assert_eq!(n_open, n_close, "unbalanced {open}{close} in {js}");
    }
}

#[test]
fn kernel_health_counters_are_exactly_predicted() {
    let _hub = hub_lock();
    let t = telemetry::global();
    // truncated(2) is a narrow frame (f + sig + headroom fits i128), so
    // one 2-term reduce is exactly one narrow block sweep over two lanes,
    // and the dropped small term activates sticky on that one block.
    let spec = AccSpec::truncated(2);
    let plan = ReducePlan::with_backend(spec, registry::sel("kernel").expect("registered"));
    let _warm = plan.reducer(); // forces backend-slot registration
    let fam = t.reduce_slot(backend_slot("kernel"));
    let k = &t.kernel;
    let before = (
        k.block_sweeps.get(),
        k.lanes.get(),
        k.narrow_blocks.get(),
        k.wide_blocks.get(),
        k.sticky_activations.get(),
        fam.reduce_calls.get(),
        fam.ingest_terms.get(),
    );
    let out = plan.reduce(&sticky_pair());
    assert!(out.sticky, "the probe pair must drop bits");
    assert_eq!(k.block_sweeps.get() - before.0, 1, "one block sweep");
    assert_eq!(k.lanes.get() - before.1, 2, "two SoA lanes");
    assert_eq!(k.narrow_blocks.get() - before.2, 1, "narrow i128 path");
    assert_eq!(k.wide_blocks.get() - before.3, 0, "wide path untouched");
    assert_eq!(k.sticky_activations.get() - before.4, 1, "one sticky block");
    assert_eq!(fam.reduce_calls.get() - before.5, 1);
    assert_eq!(fam.ingest_terms.get() - before.6, 2);
}

#[test]
fn eia_drain_health_counters_are_exactly_predicted() {
    let _hub = hub_lock();
    let t = telemetry::global();
    let spec = AccSpec::truncated(2);
    // Order-invariance under a truncated spec negotiates to the EIA; the
    // build itself must land in exactly one plan-rationale bucket.
    let p = &t.plan;
    let before_builds = p.builds.get();
    let before_oi = p.negotiated_order_invariant.get();
    let plan = ReducePlan::builder(spec)
        .require_order_invariant()
        .build()
        .expect("eia satisfies order-invariance");
    assert_eq!(plan.backend().name(), "eia");
    assert_eq!(p.builds.get() - before_builds, 1);
    assert_eq!(p.negotiated_order_invariant.get() - before_oi, 1);
    // One reduce = one drain reconciling both occupied bins (the two terms
    // bank at distinct effective exponents), with sticky from the dropped
    // small term; the occupancy histogram sees exactly one observation.
    let a = &t.accum;
    let before = (a.drains.get(), a.drain_bins.get(), a.drain_sticky.get(), a.occupancy.count());
    let out = plan.reduce(&sticky_pair());
    assert!(out.sticky, "the probe pair must drop bits");
    assert_eq!(a.drains.get() - before.0, 1, "one reconcile-and-align drain");
    assert_eq!(a.drain_bins.get() - before.1, 2, "two occupied bins swept");
    assert_eq!(a.drain_sticky.get() - before.2, 1, "the drain carried sticky");
    assert_eq!(a.occupancy.count() - before.3, 1, "one occupancy observation");
}

#[test]
fn spill_and_wide_bank_promotions_count_exactly() {
    let _hub = hub_lock();
    let t = telemetry::global();
    let a = &t.accum;

    // Storage layer, driven directly: two banks of 2^61 + 1 stay on the
    // fast i64 lane individually but cross the 2^62 spill threshold on the
    // second add — exactly one promotion. A value an i64 cannot hold banks
    // straight onto the wide lane — exactly one wide bank.
    let before = (a.spills.get(), a.wide_banks.get());
    let mut bins = ExpBins::new();
    let step = (1i128 << 61) + 1;
    bins.bank_wide(3, step);
    assert_eq!(a.spills.get() - before.0, 0, "first bank stays on the fast lane");
    bins.bank_wide(3, step);
    assert_eq!(a.spills.get() - before.0, 1, "second bank promotes exactly once");
    assert_eq!(a.wide_banks.get() - before.1, 0, "fast-lane traffic never banks wide");
    bins.bank_wide(5, 1i128 << 70);
    assert_eq!(a.wide_banks.get() - before.1, 1, "i64-overflowing value banks wide");
    assert_eq!(bins.value(3), 2 * step, "the promotion loses no bits");
    assert_eq!(bins.value(5), 1i128 << 70);

    // Backend route: absorbing the same deferred peer checkpoint twice
    // accumulates its bin onto the reducer's fast lane, crossing the
    // threshold inside the second merge — the spill counter must move by
    // exactly one, and both absorbs land on the eia lifecycle slot.
    let plan = ReducePlan::with_backend(AccSpec::truncated(2), registry::sel("eia").expect("eia"));
    let mut r = plan.reducer();
    let fam = t.reduce_slot(backend_slot("eia"));
    let snap = || EiaSnapshot { max_lambda: 80, terms: 2, bins: vec![(60, step)] };
    let before = (a.spills.get(), a.wide_banks.get(), fam.absorbs.get());
    r.absorb(&Partial::deferred(snap()));
    r.absorb(&Partial::deferred(snap()));
    assert_eq!(a.spills.get() - before.0, 1, "the second absorb crosses 2^62");
    assert_eq!(a.wide_banks.get() - before.1, 0);
    assert_eq!(fam.absorbs.get() - before.2, 2);
    assert_eq!(r.terms(), 4, "checkpoint term counts accumulate");
}

#[test]
fn stream_service_exposition_carries_format_and_shard_labels() {
    let _hub = hub_lock();
    // The one test allowed to reset the hub: service-level Prometheus
    // output is goldened on absolute values, and the lock guarantees no
    // concurrent writer (every instrumented path in this binary runs under
    // the same mutex).
    telemetry::global().reset();
    let svc = StreamService::exact(BF16);
    let terms: Vec<Fp> = (0..5).map(|i| Fp::from_f64(i as f64 + 0.5, BF16)).collect();
    svc.ingest("telemetry-labels", terms).expect("queue accepts one batch");
    let drained = svc.drain("telemetry-labels");
    assert!(drained.is_some(), "the ingested stream must exist");

    let snap = svc.telemetry_snapshot();
    assert_eq!(snap.counter_labeled("ofa_service_batches", "format", "BF16"), 1);
    assert_eq!(snap.counter_labeled("ofa_service_ingested_terms", "format", "BF16"), 5);
    assert_eq!(snap.counter_labeled("ofa_service_drains", "format", "BF16"), 1);
    // The engine negotiated the kernel backend (exact spec); its slot saw
    // at least the five ingested terms (merge traffic may add more).
    assert!(snap.counter_labeled("ofa_reduce_ingest_terms", "backend", "kernel") >= 5);

    let prom = svc.stats_prometheus();
    assert!(prom.contains("ofa_service_batches_total{format=\"BF16\"} 1"), "{prom}");
    assert!(prom.contains("ofa_service_ingested_terms_total{format=\"BF16\"} 5"), "{prom}");
    assert!(prom.contains("ofa_stream_batches_total 1"), "{prom}");
    assert!(prom.contains("ofa_stream_batch_terms_total 5"), "{prom}");
    // Drain quiesces the queue before reporting, so the gauge settles.
    assert!(prom.contains("ofa_stream_queue_depth 0"), "{prom}");
    // The segment merged into some shard stripe; which one is a hash
    // detail, but the labeled series must exist.
    assert!(prom.contains("shard=\""), "{prom}");
    assert!(svc.stats_json().contains("\"ofa_service_ingested_terms\""));
}
