//! Design-space explorer: sweep every mixed-radix configuration of an
//! N-term adder for a chosen format, with workload-driven power, and print
//! the Pareto frontier — the tool a hardware team would actually run when
//! sizing a fused accumulator for their datatype.
//!
//! Run: `cargo run --release --example dse_explorer -- --format e4m3 --n 32`

use online_fp_add::coordinator::Coordinator;
use online_fp_add::dse::{sweep_format, SweepOptions};
use online_fp_add::formats::format_by_name;
use online_fp_add::util::cli::Args;
use online_fp_add::util::table::Table;
use online_fp_add::workload::bert::power_trace;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let fmt = format_by_name(args.get_or("format", "bf16")).expect("unknown --format");
    let n = args.get_usize("n", 32).unwrap() as u32;
    let clock = args.get_f64("clock", 1.0).unwrap();
    let vectors = args.get_usize("vectors", 256).unwrap();

    let coord = Coordinator::default_parallelism().verbose(true);
    let trace = Arc::new(power_trace(fmt, n as usize, vectors, 0xD5E));
    println!(
        "exploring {} {n}-term adders @ {clock} ns on a BERT/GLUE trace \
         (spread {:.1} octaves, {:.0}% zero lanes)\n",
        fmt,
        trace.mean_exponent_spread(),
        100.0 * trace.zero_fraction()
    );
    let opts = SweepOptions { clock_ns: clock, ..Default::default() };
    let points = sweep_format(fmt, n, &opts, Some(trace), &coord);

    let base = points[0].clone();
    let mut t = Table::new(vec!["config", "area µm²", "Δ area", "power mW", "Δ power", "pareto"]);
    // Pareto: not dominated in (area, power).
    let dominated = |i: usize| {
        points.iter().enumerate().any(|(j, q)| {
            j != i
                && q.area_um2 <= points[i].area_um2
                && q.power_mw.unwrap_or(f64::MAX) <= points[i].power_mw.unwrap_or(f64::MAX)
                && (q.area_um2 < points[i].area_um2
                    || q.power_mw.unwrap_or(f64::MAX) < points[i].power_mw.unwrap_or(f64::MAX))
        })
    };
    for (i, p) in points.iter().enumerate() {
        let pw = p.power_mw.unwrap_or(0.0);
        t.row(vec![
            p.config.to_string(),
            format!("{:.0}", p.area_um2),
            format!("{:+.1}%", 100.0 * (p.area_um2 - base.area_um2) / base.area_um2),
            format!("{pw:.2}"),
            format!(
                "{:+.1}%",
                100.0 * (pw - base.power_mw.unwrap_or(1.0)) / base.power_mw.unwrap_or(1.0)
            ),
            if dominated(i) { "" } else { "◆" }.into(),
        ]);
    }
    println!("{}", t.render());
    println!("◆ = Pareto-optimal in (area, power); first row is the paper's baseline.");
}
