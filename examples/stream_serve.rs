//! Serving demo for the `stream` tier: replay a BERT partial-product trace
//! through the sharded streaming align-and-add engine as live traffic from
//! concurrent clients, verify every stream **bit-exactly** against the
//! `⊙`-tree reference, then demonstrate the invariance that makes the
//! design safe — chunk size, thread count and arrival order cannot change
//! a single bit of any stream's `(λ, acc, sticky)` state in exact mode.
//!
//! Run: `cargo run --release --example stream_serve`
//! Knobs: `--vectors 512 --streams 8 --clients 8 --threads 0` (0 = auto),
//! `--backend scalar|kernel[:block]|eia` (chunk-reduction backend by
//! registry name; omit to let the plan builder negotiate), `--stats`
//! (dump the cross-tier telemetry as Prometheus text after the replay),
//! `--provenance` (print each verified stream's numeric audit record —
//! spec, plan, work counts, resolved state, order-invariant hash).

use online_fp_add::arith::tree::{tree_sum, RadixConfig};
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{Fp, BF16};
use online_fp_add::reduce::BackendSel;
use online_fp_add::stream::{EngineConfig, StreamService};
use online_fp_add::util::cli::Args;
use online_fp_add::util::prng::XorShift;
use online_fp_add::workload::bert::power_trace;
use std::time::Instant;

const N_TERMS: usize = 32;

fn main() {
    let args = Args::from_env();
    let vectors = args.get_usize("vectors", 512).unwrap();
    let streams = args.get_usize("streams", 8).unwrap().max(1);
    let clients = args.get_usize("clients", 8).unwrap().max(1);
    let threads = args.get_usize("threads", 0).unwrap();
    // Backend by registry name; None lets ReducePlan::negotiate pick.
    let backend: Option<BackendSel> = args.get("backend").map(|s| {
        s.parse::<BackendSel>().unwrap_or_else(|e: String| {
            eprintln!("--backend: {e}");
            std::process::exit(2);
        })
    });

    let spec = AccSpec::exact(BF16);
    println!("extracting BERT partial-product trace ({vectors} vectors × {N_TERMS} lanes)...");
    let trace = power_trace(BF16, N_TERMS, vectors, 0xBE27);
    println!(
        "trace: {} vectors, exponent spread {:.1} octaves, {:.0}% zero lanes",
        trace.len(),
        trace.mean_exponent_spread(),
        100.0 * trace.zero_fraction()
    );

    // Reference: one ⊙ tree per stream over its flattened term history.
    let streams = streams.min(trace.len().max(1)); // every stream gets rows
    let mut per_stream: Vec<Vec<Fp>> = vec![Vec::new(); streams];
    for (i, row) in trace.vectors.iter().enumerate() {
        per_stream[i % streams].extend_from_slice(row);
    }
    let references: Vec<_> = per_stream
        .iter()
        .map(|ts| tree_sum(ts, &RadixConfig::baseline(ts.len() as u32), spec))
        .collect();

    // ---- live replay: concurrent clients feeding the service -----------
    let mut cfg = EngineConfig { spec, backend, ..Default::default() };
    if threads > 0 {
        cfg.threads = threads;
    }
    let svc = StreamService::new(BF16, cfg);
    println!("reduction plan: {}", svc.engine().plan().describe());
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let svc = &svc;
            let rows = &trace.vectors;
            scope.spawn(move || {
                // Client c replays every row i with i % clients == c.
                for (i, row) in rows.iter().enumerate() {
                    if i % clients == c {
                        svc.ingest_blocking(&format!("bert-{}", i % streams), row.clone())
                            .expect("engine alive");
                    }
                }
            });
        }
    });
    let (queued_s, total_terms) =
        (t0.elapsed().as_secs_f64(), (trace.len() * N_TERMS) as f64);
    svc.engine().quiesce();
    let drained_s = t0.elapsed().as_secs_f64();
    let m = svc.engine().metrics();
    println!(
        "\ningested {} batches / {} terms from {clients} clients on {} worker threads",
        m.batches.get(),
        m.ingested_terms.get(),
        svc.engine().threads()
    );
    println!(
        "throughput: {:.2} M terms/s (queue drained in {drained_s:.3}s, submit {queued_s:.3}s)",
        total_terms / drained_s / 1e6
    );
    println!("ingest latency: {}", m.ingest_latency.summary());

    // ---- bit-exact verification against the ⊙-tree reference ------------
    let mut bad = 0usize;
    for (s, want) in references.iter().enumerate() {
        let (value, snap) = svc.query(&format!("bert-{s}")).expect("stream exists");
        if snap.state() != *want || snap.terms != per_stream[s].len() as u64 {
            eprintln!("stream bert-{s}: state mismatch vs tree_sum");
            bad += 1;
        } else {
            println!(
                "bert-{s}: {:>6} terms  λ={:>3}  Σ={:<12}  ({} segments)",
                snap.terms,
                snap.lambda,
                value.to_f64(),
                snap.segments
            );
        }
    }

    // Cross-tier observability: `--stats` renders the global hub plus this
    // service's `ofa_service_*` series in Prometheus text exposition — the
    // same output `repro stats --prometheus` serves.
    if args.has("stats") {
        println!("\n--- telemetry (Prometheus exposition) ---");
        print!("{}", svc.stats_prometheus());
    }

    // Numeric provenance: the audit record behind each served sum. The
    // hash covers value facts only, so re-running with any --backend,
    // --threads or client count prints the same hash per stream.
    if args.has("provenance") {
        println!("\n--- numeric provenance (first {} streams) ---", streams.min(4));
        for s in 0..streams.min(4) {
            if let Some((_, rec)) = svc.query_with_provenance(&format!("bert-{s}")) {
                println!("{}", rec.render());
            }
        }
    }

    // ---- invariance sweep: chunk × threads × shuffled arrival ----------
    println!("\ninvariance sweep (exact mode): chunk ∈ {{1,7,64}}, threads ∈ {{1,2,4,8}}, shuffled arrival");
    let mut rng = XorShift::new(0x0DDE);
    let mut runs = 0usize;
    for threads in [1usize, 2, 4, 8] {
        for chunk in [1usize, 7, 64] {
            let mut order: Vec<usize> = (0..trace.vectors.len()).collect();
            rng.shuffle(&mut order);
            let svc = StreamService::new(
                BF16,
                EngineConfig { threads, chunk, spec, backend, ..Default::default() },
            );
            for &i in &order {
                svc.ingest_blocking(&format!("bert-{}", i % streams), trace.vectors[i].clone())
                    .expect("engine alive");
            }
            for (s, want) in references.iter().enumerate() {
                let snap = svc.checkpoint(&format!("bert-{s}")).expect("stream exists");
                if snap.state() != *want {
                    eprintln!("DIVERGED: threads={threads} chunk={chunk} stream={s}");
                    bad += 1;
                }
            }
            runs += 1;
        }
    }
    println!("{runs} replays × {streams} streams: all snapshots bit-identical to tree_sum ✓");

    if bad > 0 {
        eprintln!("{bad} mismatches");
        std::process::exit(1);
    }
    println!("\nall stream states bit-exact vs the Rust ⊙ tree ✓");
}
