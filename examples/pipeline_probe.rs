//! Diagnostic probe: area/register breakdown of 32-term BFloat16 adders at
//! the paper's 1 GHz operating point for every radix configuration.
//! Useful when calibrating the hardware model (DESIGN.md §Calibration).

use online_fp_add::arith::tree::{enumerate_configs, RadixConfig};
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::BF16;
use online_fp_add::hw::datapath::{build_adder, DatapathParams};
use online_fp_add::hw::pipeline::{min_clock_ns, paper_stages};
use online_fp_add::hw::{design, gates};
use online_fp_add::util::table::Table;

fn main() {
    let fmt = BF16;
    let n = 32;
    let clock = 1.0;
    let stages = paper_stages(fmt, n);
    println!("32-term BFloat16 @ {clock} ns, {stages} stages\n");
    let mut t = Table::new(vec![
        "config", "comb µm²", "reg bits", "total µm²", "Δ vs base", "comb ns", "minclk@k",
    ]);
    let base = design::evaluate_area(fmt, n, &RadixConfig::baseline(n), clock);
    let mut configs = enumerate_configs(n);
    configs.sort_by_key(|c| c.levels());
    for cfg in configs {
        let p = design::evaluate_area(fmt, n, &cfg, clock);
        let params = DatapathParams::new(fmt, n, AccSpec::hw_default(fmt, n as usize));
        let adder = build_adder(params, &cfg);
        let comb = gates::ge_to_um2(adder.nl.area());
        let minclk = min_clock_ns(&adder, stages);
        t.row(vec![
            format!("{cfg}{}", if p.feasible { "" } else { " (!)" }),
            format!("{comb:.0}"),
            format!("{}", p.reg_bits),
            format!("{:.0}", p.area_um2),
            format!("{:+.1}%", 100.0 * (p.area_um2 - base.area_um2) / base.area_um2),
            format!("{:.2}", p.comb_delay_ns),
            format!("{minclk:.2}"),
        ]);
    }
    println!("{}", t.render());
}
