//! Internal profiling target for the §Perf pass: hammer the two hot paths
//! (bit-accurate ⊙ tree and the activity simulator) for a few seconds.
use online_fp_add::arith::tree::{tree_sum, RadixConfig};
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{Fp, BF16};
use online_fp_add::hw::datapath::DatapathParams;
use online_fp_add::hw::power::ActivitySim;
use online_fp_add::util::prng::XorShift;

fn main() {
    let mut rng = XorShift::new(1);
    let vecs: Vec<Vec<Fp>> =
        (0..256).map(|_| (0..32).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect()).collect();
    let spec = AccSpec::hw_default(BF16, 32);
    let cfg: RadixConfig = "8-2-2".parse().unwrap();
    let mode = std::env::args().nth(1).unwrap_or_else(|| "tree".into());
    match mode.as_str() {
        "tree" => {
            for _ in 0..20000 {
                for v in &vecs {
                    std::hint::black_box(tree_sum(v, &cfg, spec));
                }
            }
        }
        "power" => {
            let params = DatapathParams::new(BF16, 32, spec);
            let mut sim = ActivitySim::new(params, &cfg);
            for _ in 0..20000 {
                for v in &vecs {
                    sim.step(v);
                }
            }
        }
        _ => panic!("tree|power"),
    }
}
