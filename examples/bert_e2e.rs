//! End-to-end driver (DESIGN.md §E2E): every layer of the stack
//! composes on a real workload.
//!
//! 1. **L2 via PJRT** — load the AOT-compiled `bert_layer` artifact and run
//!    GLUE-like sentences through a BERT encoder layer (python never runs).
//! 2. **Trace extraction** — rebuild the N-term partial-product vectors the
//!    layer's matmuls feed through 32-term BFloat16 fused adders.
//! 3. **L1 via PJRT + L3 batcher** — serve every vector through the Pallas
//!    online `⊙` reduction artifact behind the dynamic batcher, from
//!    concurrent client threads, and verify each result **bit-exactly**
//!    against the Rust `⊙`-tree model; report latency/throughput.
//! 4. **Hardware evaluation** — run the same trace through the
//!    switching-activity power model for the baseline and the paper's best
//!    32-term BF16 configuration (8-2-2) and report the Table I(b) row.
//!
//! Run: `make artifacts && cargo run --release --example bert_e2e`

use online_fp_add::arith::tree::{tree_sum, RadixConfig};
use online_fp_add::arith::AccSpec;
use online_fp_add::coordinator::batcher::{Batcher, BatcherConfig};
use online_fp_add::formats::BF16;
use online_fp_add::hw::datapath::DatapathParams;
use online_fp_add::hw::design::{attach_power, evaluate_area};
use online_fp_add::hw::power::ActivitySim;
use online_fp_add::runtime::{BertLayerExe, BertWeights, Runtime};
use online_fp_add::util::cli::Args;
use online_fp_add::util::prng::XorShift;
use online_fp_add::workload::glue::{GlueConfig, GlueCorpus};
use online_fp_add::workload::partial_product_trace;
use online_fp_add::workload::Trace;
use std::sync::Arc;
use std::time::Instant;

const N_TERMS: usize = 32;
const GUARD: u32 = 16; // Frame.hw_default(8, 7, 32) baked into the artifact

fn main() {
    let args = Args::from_env();
    let sentences = args.get_usize("sentences", 4).unwrap();
    let vectors_per_mm = args.get_usize("vectors", 160).unwrap();

    // ---- 1. L2 forward passes through PJRT ------------------------------
    let dir = Runtime::default_artifact_dir();
    if !dir.join("bert_layer.hlo.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let rt = Runtime::new(&dir).expect("PJRT CPU client");
    println!("PJRT platform: {}", rt.platform());
    let layer = BertLayerExe::load(&rt).expect("bert_layer artifact");
    let weights = BertWeights::random(0xBE27);
    let corpus = GlueCorpus::new(GlueConfig::default(), 0x617E);
    let (seq, d) = online_fp_add::runtime::bert_dims();

    let mut rng = XorShift::new(0xE2E);
    let mut trace = Trace::new(BF16, N_TERMS);
    let t0 = Instant::now();
    for s in 0..sentences {
        let x = corpus.embed_sentence(&mut rng);
        let acts = layer.run(&rt, &x, &weights).expect("bert layer forward");
        // ---- 2. partial-product traces from three of the layer matmuls --
        for (name, a, b, shape) in [
            ("q_proj", &x, &weights.wq, (seq, d, d)),
            ("ctx", &acts.attn, &acts.v, (seq, seq, d)),
            ("ffn1", &acts.h, &weights.w1, (seq, d, weights.w1.len() / d)),
        ] {
            let t = partial_product_trace(a, b, shape, BF16, N_TERMS, vectors_per_mm, s as u64);
            trace.vectors.extend(t.vectors);
            let _ = name;
        }
    }
    println!(
        "ran {sentences} sentences through the PJRT BERT layer in {:.2}s; \
         extracted {} adder vectors (exponent spread {:.1} octaves, {:.0}% zero lanes)",
        t0.elapsed().as_secs_f64(),
        trace.len(),
        trace.mean_exponent_spread(),
        100.0 * trace.zero_fraction()
    );

    // ---- 3. serve every vector through the Pallas artifact --------------
    let spec = AccSpec::truncated(GUARD);
    let batcher = Batcher::spawn_with(
        BatcherConfig {
            n_terms: N_TERMS,
            linger: std::time::Duration::from_micros(300),
            ..Default::default()
        },
        {
            let dir = dir.clone();
            move || {
                let rt = Runtime::new(dir).expect("PJRT client (dispatcher)");
                let exe = online_fp_add::runtime::OnlineReduceExe::load_bf16_n32(&rt)
                    .expect("reduce artifact");
                move |rows: &[(Vec<i32>, Vec<i32>)]| {
                    let mut e_all = Vec::new();
                    let mut m_all = Vec::new();
                    for (e, m) in rows {
                        e_all.extend_from_slice(e);
                        m_all.extend_from_slice(m);
                    }
                    let out = exe.run(&rt, &e_all, &m_all).expect("pjrt execute");
                    out.lambda.into_iter().zip(out.acc).collect::<Vec<_>>()
                }
            }
        },
    );
    let handle = batcher.handle();
    let vectors = Arc::new(trace.vectors.clone());
    let t1 = Instant::now();
    let clients = 8usize;
    let mismatches: usize = std::thread::scope(|scope| {
        (0..clients)
            .map(|c| {
                let h = handle.clone();
                let vecs = Arc::clone(&vectors);
                scope.spawn(move || {
                    let mut bad = 0usize;
                    for v in vecs.iter().skip(c).step_by(clients) {
                        // (effective exponent, signed significand) lanes —
                        // subnormals travel as (1, ±mantissa).
                        let e: Vec<i32> = v.iter().map(|t| t.eff_exp()).collect();
                        let m: Vec<i32> = v.iter().map(|t| t.signed_sig() as i32).collect();
                        let resp = h.reduce(e, m).expect("batched reduce");
                        let want =
                            tree_sum(v, &RadixConfig::baseline(N_TERMS as u32), spec);
                        if resp.lambda != want.lambda
                            || resp.acc != want.acc.to_i128() as i64
                        {
                            bad += 1;
                        }
                    }
                    bad
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .sum()
    });
    let served = trace.len();
    let dt = t1.elapsed().as_secs_f64();
    let m = batcher.metrics();
    println!(
        "served {served} reductions through the Pallas ⊙ artifact in {dt:.2}s \
         ({:.0} req/s, {clients} clients)",
        served as f64 / dt
    );
    println!(
        "batching: {} batches, mean fill {:.1}; latency {}",
        m.batches.get(),
        m.mean_batch_fill(),
        m.latency.summary()
    );
    assert_eq!(mismatches, 0, "PJRT vs Rust ⊙-tree mismatch");
    println!("all {served} results match the Rust bit-accurate ⊙ tree exactly ✓");

    // ---- 4. hardware evaluation on the same trace ------------------------
    println!("\nhardware evaluation on this trace (paper Table I(b), BFloat16 row):");
    for cfgs in ["32", "8-2-2"] {
        let c: RadixConfig = cfgs.parse().unwrap();
        let mut point = evaluate_area(BF16, N_TERMS as u32, &c, 1.0);
        attach_power(&mut point, &trace.vectors);
        println!(
            "  {:<8} area {:>6.0} µm²  power {:>5.2} mW  ({} @ {:.2} ns, {} stages)",
            cfgs,
            point.area_um2,
            point.power_mw.unwrap(),
            if point.feasible { "meets clock" } else { "min clock" },
            point.clock_ns,
            point.stages,
        );
    }
    // Quick activity sanity: the sim must agree with the arith model.
    let params = DatapathParams::new(BF16, N_TERMS as u32, AccSpec::hw_default(BF16, N_TERMS));
    let mut sim = ActivitySim::new(params, &"8-2-2".parse().unwrap());
    for v in trace.vectors.iter().take(64) {
        sim.step(v);
    }
    let want = tree_sum(&trace.vectors[63], &"8-2-2".parse().unwrap(), AccSpec::hw_default(BF16, N_TERMS));
    assert_eq!(sim.last_state().0, want.lambda as i64);
    println!("\nE2E complete: L2 (PJRT BERT) → trace → L1 (Pallas ⊙, batched) → L3 hardware models ✓");
}
