//! Quickstart: the paper's core result in thirty lines.
//!
//! Builds a 16-term BFloat16 fused adder four ways — the serial baseline
//! (Algorithm 2), the online recurrence (Algorithm 3), a mixed-radix `⊙`
//! tree (eq. 9) and the exact Kulisch oracle — and shows they all produce
//! the *identical correctly-rounded sum*, then prints what the hardware
//! models say each architecture costs.
//!
//! Run: `cargo run --release --example quickstart`

use online_fp_add::arith::adder::{Architecture, MultiTermAdder};
use online_fp_add::arith::tree::RadixConfig;
use online_fp_add::formats::{Fp, BF16};
use online_fp_add::hw::design::evaluate_area;
use online_fp_add::util::prng::XorShift;

fn main() {
    // 16 BFloat16 values with a wild exponent spread.
    let mut rng = XorShift::new(2024);
    let terms: Vec<Fp> = (0..16).map(|_| rng.gen_fp_gauss(BF16, 100.0)).collect();
    println!("inputs: {:?}\n", terms.iter().map(|t| t.to_f64()).collect::<Vec<_>>());

    let architectures = [
        ("baseline  (Algorithm 2)", Architecture::Baseline),
        ("online    (Algorithm 3)", Architecture::Online),
        ("tree 8-2  (eq. 9)", Architecture::Tree("8-2".parse().unwrap())),
        ("tree 4-2-2", Architecture::Tree("4-2-2".parse().unwrap())),
        ("exact     (Kulisch oracle)", Architecture::Exact),
    ];
    let mut sums = Vec::new();
    for (name, arch) in architectures {
        let adder = MultiTermAdder::exact(BF16, 16, arch);
        let s = adder.add(&terms);
        println!("{name:<28} Σ = {:<12} bits {:#06x}", s.to_f64(), s.bits);
        sums.push(s.bits);
    }
    assert!(sums.windows(2).all(|w| w[0] == w[1]), "all architectures must agree");
    println!("\nall five architectures agree bit-exactly ✓\n");

    // What the hardware models think of the same three designs @ 1 GHz.
    println!("hardware cost @ 1 GHz (paper §IV operating point):");
    for cfg in ["16", "8-2", "4-2-2"] {
        let c: RadixConfig = cfg.parse().unwrap();
        let p = evaluate_area(BF16, 16, &c, 1.0);
        println!(
            "  {:<8} area {:>6.0} µm²  regs {:>4} bits  comb {:.2} ns  {}",
            cfg,
            p.area_um2,
            p.reg_bits,
            p.comb_delay_ns,
            if p.feasible { "meets 1 GHz" } else { "needs slower clock" }
        );
    }
}
